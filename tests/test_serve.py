"""Tests for `repro.exp.serve`: signature bucketing (compile counts),
packing bit-identity against the batch runner, tenant fairness under a
starvation adversary, and checkpoint/resume bit-identity — mid-run and
across a warm-fault epoch boundary."""
import io
import json
from pathlib import Path

import pytest

from repro.core.engine import clear_aot_cache, compile_counter
from repro.exp import clear_caches, get_scenario, run_experiment
from repro.exp.serve import SimService, clear_serve_caches, lower_request


def _submit_all(svc, named):
    """[(tenant, scenario)] -> {rid: (tenant, scenario)}."""
    return {svc.submit(get_scenario(s), tenant=t): (t, s)
            for t, s in named}


def _records(text):
    return [json.loads(line) for line in text.splitlines() if line]


# ---------------------------------------------------------------------------
# bucketing: total compiles == distinct signature buckets
# ---------------------------------------------------------------------------

def test_bucketing_compile_count_equals_distinct_signatures():
    """Three requests, two distinct signatures: the second `smoke`
    submission (another tenant) shares the first's bucket executable,
    so the whole mixed run costs exactly two compiles."""
    clear_caches()
    clear_serve_caches()
    clear_aot_cache()
    specs = [("alice", "smoke"), ("bob", "smoke"),
             ("carol", "smoke_faults")]
    buckets = set()
    for rid, (t, s) in enumerate(specs, start=1):
        units, _ = lower_request(get_scenario(s), rid, t, 0)
        buckets.update(u.bucket for u in units)
    assert len(buckets) == 2

    before = compile_counter()
    svc = SimService(window=100)
    rids = _submit_all(svc, specs)
    svc.run()
    assert svc.idle
    assert compile_counter() - before == len(buckets)
    for rid in rids:
        assert all(r is not None for cell in svc.results(rid)
                   for r in cell)


# ---------------------------------------------------------------------------
# packing: per-lane results bit-identical to per-spec run_experiment
# ---------------------------------------------------------------------------

def test_packed_results_bit_identical_to_batch_runner():
    """Heterogeneous tenants packed into shared dispatches must return
    the same `SimResult`s (field-for-field, float-for-float) as
    individual batch runs of their specs."""
    svc = SimService(window=100)
    rids = _submit_all(svc, [("alice", "smoke"), ("bob", "smoke_faults")])
    svc.run()
    for rid, (_, name) in rids.items():
        spec = get_scenario(name)
        batch = run_experiment(spec, verbose=False)
        served = svc.results(rid)
        for ci, g in enumerate(batch.grids):
            R, S = len(g.rates), len(g.seeds)
            for fi in range(len(g.fault_labels)):
                for ri in range(R):
                    for si in range(S):
                        assert (served[ci][(fi * R + ri) * S + si]
                                == g.results[fi][ri][si]), (name, ci, fi,
                                                            ri, si)


# ---------------------------------------------------------------------------
# fairness: a small tenant is not starved by a flooding one
# ---------------------------------------------------------------------------

def test_small_tenant_ages_past_flooding_tenant():
    """Adversary: `big` floods four requests into one bucket before
    `small` submits a single request into another.  With bounded slots,
    pure FIFO would run `small` last; the min-(tenant-load, seq) policy
    activates it next to big's first pack instead, so it completes
    before big's backlog."""
    out = io.StringIO()
    svc = SimService(out=out, window=64, pack=4, max_active=2)
    big = [svc.submit(get_scenario("smoke"), tenant="big")
           for _ in range(4)]
    small = svc.submit(get_scenario("smoke_faults"), tenant="small")
    svc.run()
    done_order = [r["request"] for r in _records(out.getvalue())
                  if r["kind"] == "done"]
    assert set(done_order) == set(big) | {small}
    # small finished ahead of every big request but the one it ran
    # alongside — in particular ahead of big's LAST request
    assert done_order.index(small) < done_order.index(big[-1])
    assert done_order.index(small) <= 2


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def _serve_to_jsonl(path, state_dir, *, max_rounds=None, resume=False):
    if resume:
        svc = SimService.resume(str(state_dir), out=str(path))
    else:
        svc = SimService(out=str(path), window=100,
                         state_dir=str(state_dir), checkpoint_every=1)
        _submit_all(svc, [("alice", "smoke"),
                          ("bob", "smoke_warm_faults")])
    svc.run(max_rounds=max_rounds)
    svc.close()
    return svc


def test_kill_and_resume_bit_identical(tmp_path):
    """A service killed mid-run (after its warm-fault request crossed an
    epoch boundary) and resumed from the latest snapshot must append the
    exact bytes the uninterrupted run would have written, and its final
    results must equal the batch runner's."""
    base = _serve_to_jsonl(tmp_path / "base.jsonl", tmp_path / "ck_base")
    assert base.idle

    # killed at round 2 = cycle 200: past smoke_warm_faults' onset (151),
    # so the snapshot holds mid-schedule epoch state — and mid-run for
    # both requests (smoke budget 250, warm budget 382)
    killed = _serve_to_jsonl(tmp_path / "kr.jsonl", tmp_path / "ck",
                             max_rounds=2)
    assert not killed.idle
    resumed = _serve_to_jsonl(tmp_path / "kr.jsonl", tmp_path / "ck",
                              resume=True)
    assert resumed.idle

    assert ((tmp_path / "kr.jsonl").read_bytes()
            == (tmp_path / "base.jsonl").read_bytes())

    # resumed results == batch runner results (only smoke_warm_faults'
    # lanes are guaranteed unfinished at the kill; check both anyway
    # for every lane the resumed process finished)
    for rid, name in ((1, "smoke"), (2, "smoke_warm_faults")):
        g = run_experiment(get_scenario(name), verbose=False).grids[0]
        R, S = len(g.rates), len(g.seeds)
        served = resumed.results(rid)
        checked = 0
        for fi in range(len(g.fault_labels)):
            for ri in range(R):
                for si in range(S):
                    res = served[0][(fi * R + ri) * S + si]
                    if res is not None:   # finished pre-kill lanes live
                        assert res == g.results[fi][ri][si]
                        checked += 1
        assert checked > 0


def test_resume_requires_snapshot(tmp_path):
    with pytest.raises(FileNotFoundError):
        SimService.resume(str(tmp_path / "nothing"))


def test_run_cli_jsonl_matches_serve_schema(tmp_path):
    """`python -m repro.exp.run --jsonl` result records must be
    value-identical to the service's for the same scenario (modulo the
    tenant/request identity fields)."""
    from repro.exp.run import main as run_main

    path = tmp_path / "batch.jsonl"
    rc = run_main(["--scenario", "smoke", "--quiet",
                   "--out", str(tmp_path / "b.json"),
                   "--jsonl", str(path)])
    assert rc == 0
    out = io.StringIO()
    svc = SimService(out=out, window=100)
    svc.submit(get_scenario("smoke"), tenant="batch")
    svc.run()

    def key(r):
        return (r["cell"], r["lane"])

    def strip(r):
        return {k: v for k, v in r.items() if k not in ("request",)}

    batch = {key(r): strip(r) for r in _records(path.read_text())
             if r["kind"] == "result"}
    serve = {key(r): strip(r) for r in _records(out.getvalue())
             if r["kind"] == "result"}
    assert batch == serve


def test_windows_doc_example_paths_exist():
    """The docs reference these import paths; keep them live."""
    from repro.exp import windows
    assert windows.SCHEMA_VERSION == 1
    rec = windows.done_record(request=1, tenant="t", scenario="s",
                              lanes=2)
    assert json.loads(windows.dumps(rec))["kind"] == "done"


# ---------------------------------------------------------------------------
# multi-device pack placement: concurrent packs round-robin host devices
# ---------------------------------------------------------------------------

_DEVICE_CHILD = r"""
import json
import repro            # applies REPRO_HOST_DEVICES before jax init
import jax
from repro.exp import get_scenario
from repro.exp.serve import SimService
from repro.exp.serve import service as service_mod
from repro.exp.serve.packer import Pack

opened = []
_orig = Pack.open.__func__


def _record(cls, sid, bucket, units, **kw):
    pk = _orig(cls, sid, bucket, units, **kw)
    opened.append((sid, str(pk.device)))
    return pk


Pack.open = classmethod(_record)
svc = SimService(window=100)
svc.submit(get_scenario("smoke"), tenant="alice")
svc.submit(get_scenario("smoke_faults"), tenant="bob")
svc.run()
assert svc.idle
print(json.dumps(dict(
    ndev=len(jax.devices()),
    packs=opened,
    pd=[str(service_mod.pack_device(s)) for s in (1, 2, 3)])))
"""


def test_two_buckets_land_on_two_devices():
    """Under REPRO_HOST_DEVICES=2 two concurrently-active buckets are
    pinned to two DISTINCT devices (sid round-robin), and `pack_device`
    wraps around — placement is a pure function of the checkpointed sid,
    so resumed packs land where they left off."""
    import os
    import subprocess
    import sys

    env = dict(os.environ, REPRO_HOST_DEVICES="2")
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in sys.path if p] +
        [p for p in (env.get("PYTHONPATH") or "").split(os.pathsep) if p])
    proc = subprocess.run([sys.executable, "-c", _DEVICE_CHILD],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.splitlines()[-1])
    assert out["ndev"] == 2
    by_sid = dict(out["packs"])
    assert len(out["packs"]) >= 2
    devs = {d for d in by_sid.values()}
    assert len(devs) == 2, out["packs"]     # both devices carried a pack
    assert "None" not in devs
    # deterministic round-robin: sid 1 and 2 differ, sid 3 wraps to 1's
    assert out["pd"][0] != out["pd"][1]
    assert out["pd"][2] == out["pd"][0]


def test_pack_device_single_device_is_none():
    """Without forced devices placement opts out (engine default)."""
    import jax

    from repro.exp.serve import service as service_mod
    if len(jax.devices()) > 1:
        pytest.skip("multi-device host: pack_device pins by design")
    assert service_mod.pack_device(1) is None
    assert service_mod.pack_device(7) is None
