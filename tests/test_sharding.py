"""Sharding-rule unit tests (no devices needed: specs are pure)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as SH


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class FakePodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def _sds(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_embed_vocab_parallel():
    spec = SH.param_spec(("embed",), (122880, 2304), FakeMesh)
    assert spec[0] == "model"


def test_odd_vocab_not_sharded_on_model():
    spec = SH.param_spec(("embed",), (122753, 2304), FakeMesh)
    assert spec[0] is None


def test_attention_col_row_parallel():
    q = SH.param_spec(("blocks", "sub0", "mix", "q", "w"),
                      (40, 2304, 2304), FakeMesh)
    o = SH.param_spec(("blocks", "sub0", "mix", "o", "w"),
                      (40, 2304, 2304), FakeMesh)
    # leading dim = stacked groups, never sharded; col-parallel q shards
    # dout on model, row-parallel o shards din; FSDP adds "data" on the
    # other dim above the size threshold
    assert q[0] is None and q[2] == "model" and q[1] in (None, "data")
    assert o[0] is None and o[1] == "model" and o[2] in (None, "data")
    # below the FSDP threshold: no data sharding
    q_small = SH.param_spec(("blocks", "sub0", "mix", "q", "w"),
                            (40, 512, 512), FakeMesh)
    assert q_small == P(None, None, "model")


def test_expert_parallelism():
    spec = SH.param_spec(("blocks", "sub0", "ffn", "wi"),
                         (94, 128, 4096, 1536), FakeMesh)
    assert spec[1] == "model"              # experts across the model axis
    assert spec[2] == "data"               # FSDP within the expert


def test_router_replicated():
    spec = SH.param_spec(("blocks", "sub0", "ffn", "router"),
                         (94, 4096, 128), FakeMesh)
    assert spec == P(None, None, None)


def test_batch_specs_divisible_and_batch1():
    specs = SH.batch_specs({"tokens": _sds((256, 4096), jnp.int32)},
                           FakeMesh)
    assert specs["tokens"][0] == "data"
    # batch-1 long-context falls back to sequence sharding
    specs = SH.batch_specs({"tokens": _sds((1, 524288), jnp.int32)},
                           FakeMesh)
    assert specs["tokens"][0] is None
    assert specs["tokens"][1] == "data"


def test_pod_mesh_dp_axes():
    specs = SH.batch_specs({"tokens": _sds((512, 128), jnp.int32)},
                           FakePodMesh)
    assert specs["tokens"][0] == ("pod", "data")


def test_cache_specs_kv_and_window_sharding():
    cache = {"prelude": [], "postlude": [],
             "blocks": {"sub0": {
                 "k": _sds((40, 128, 32768, 8, 128)),
                 "v": _sds((40, 128, 32768, 8, 128)),
                 "idx": _sds((40,), jnp.int32)}}}
    specs = SH.cache_specs(cache, FakeMesh)
    kspec = specs["blocks"]["sub0"]["k"]
    assert kspec[1] == "data"              # batch
    # kv heads (8) not divisible by 16 -> window dim sharded instead
    assert kspec[2] == "model"


def test_opt_state_specs_add_data_sharding():
    pspecs = {"w": P(None, "model")}
    shapes = {"w": _sds((2304, 2304))}
    ospecs = SH.opt_state_specs(pspecs, shapes, FakeMesh)
    assert ospecs["w"] == P("data", "model")
