"""Behavioural tests of the flit-level simulator."""
import numpy as np
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.simulator import (SimConfig, Simulator,
                                  saturation_throughput)


@pytest.fixture(scope="module")
def cgroup_net():
    # single C-group: 4x4 router mesh, 16 terminals, 4 chips (Fig. 10(a))
    p = T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1)
    return T.build_switchless(p, "cgroup")


@pytest.fixture(scope="module")
def wgroup_nets():
    p = T.SwitchlessParams(a=2, b=4, m=2, n=6, noc=2, g=1)
    swl = T.build_switchless(p, "wgroup")
    swb = T.build_switch_dragonfly(
        T.SwitchDragonflyParams(t=4, l=7, gl=1, g=1), "wgroup-df")
    return swl, swb


def test_conservation_and_low_load_delivery(cgroup_net):
    cfg = SimConfig(warmup=300, measure=800, vcs_per_class=2)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    r = sim.run(0.4)
    assert r.dropped_pkts == 0
    # at low load everything offered is delivered (within transient slack)
    assert r.throughput_per_chip == pytest.approx(0.4, rel=0.12)
    # flit conservation: delivered <= generated
    assert r.delivered_pkts <= r.generated_pkts + 64 * cgroup_net.num_terminals


def test_zero_load_latency_matches_hops(cgroup_net):
    """Latency at near-zero load ~= avg hop count x per-hop latency."""
    cfg = SimConfig(warmup=300, measure=1200, vcs_per_class=2)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    r = sim.run(0.05)
    h = r.avg_hops_by_type
    expect = h["mesh"] + h["inject"] + h["eject"]  # 1 cycle per SR hop
    assert r.avg_latency == pytest.approx(expect, rel=0.5)
    assert r.avg_latency < 3 * expect


def test_intra_cgroup_saturation_beats_switch(cgroup_net):
    """Fig. 10(a): uniform saturation ~3 flits/cycle/chip, >= 2.5x the
    1 flit/cycle/chip switch-based injection cap."""
    cfg = SimConfig(warmup=400, measure=1600, vcs_per_class=4)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    sat = saturation_throughput(sim.sweep([2.5, 3.2]))
    assert sat > 2.5


def test_intra_cgroup_throughput_bounded_by_bisection(cgroup_net):
    """Accepted uniform throughput never exceeds the router-grid bisection
    bound 4/R flits/cycle/terminal (the analog of Eq. (5); the paper's n/m=3
    counts chiplet-level channel bundles, our grid has R=m*noc single
    channels across the cut)."""
    p = T.SwitchlessParams(a=1, b=1, m=2, n=6, noc=2, g=1)
    cfg = SimConfig(warmup=400, measure=1200, vcs_per_class=4)
    sim = Simulator(cgroup_net, cfg, TR.uniform(cgroup_net))
    r = sim.run(3.9)
    bound_per_chip = 4.0 / p.R * p.routers_per_chip
    assert r.throughput_per_chip <= bound_per_chip * 1.05
    # and it comes close to the paper's reported 3.0
    assert r.throughput_per_chip > 2.9


def test_switch_based_injection_cap(wgroup_nets):
    """The single terminal->switch link caps the switch-based Dragonfly at
    1 flit/cycle/chip (Sec. III-B2)."""
    _, swb = wgroup_nets
    cfg = SimConfig(warmup=400, measure=1600, vcs_per_class=2)
    sim = Simulator(swb, cfg, TR.ring_allreduce(swb, bidirectional=False))
    # the cap: never above 1 flit/cycle/chip no matter the offered load
    assert sim.run(1.0).throughput_per_chip <= 1.02
    # below the critical load the ring through a switch is conflict-free
    r = sim.run(0.9)
    assert r.throughput_per_chip > 0.82


def test_switchless_wgroup_beats_switch_based(wgroup_nets):
    """Fig. 10(c): intra-W-group uniform saturation 1.2-2x switch-based."""
    swl, swb = wgroup_nets
    cfg = SimConfig(warmup=400, measure=1200, vcs_per_class=2)
    sat_l = saturation_throughput(
        Simulator(swl, cfg, TR.uniform(swl)).sweep([1.2, 1.6]))
    sat_b = saturation_throughput(
        Simulator(swb, cfg, TR.uniform(swb)).sweep([1.2, 1.6]))
    assert sat_l > 1.15 * sat_b


def test_ring_allreduce_bidirectional_gain(cgroup_net):
    """Fig. 14(a): bidirectional ring roughly doubles the uni-ring
    saturation inside the C-group."""
    cfg = SimConfig(warmup=400, measure=1600, vcs_per_class=4)
    uni = Simulator(cgroup_net, cfg, TR.ring_allreduce(cgroup_net, False))
    bi = Simulator(cgroup_net, cfg, TR.ring_allreduce(cgroup_net, True))
    sat_u = saturation_throughput(uni.sweep([2.0, 2.6]))
    sat_b = saturation_throughput(bi.sweep([3.0, 3.8]))
    assert sat_b > 1.3 * sat_u
    assert sat_u > 1.8  # paper: ~2 flits/cycle/chip


@pytest.mark.slow
def test_nonminimal_routing_helps_worst_case():
    """Fig. 13: VAL routing beats minimal by a wide margin under the
    worst-case pattern on the full radix-16 network (one global link per
    W-group pair, so minimal WC throughput is ~1/terms-per-W-group)."""
    net = T.build_switchless(T.paper_radix16_switchless(), "wc-net")
    pat = TR.worst_case(net)
    cfg_min = SimConfig(warmup=300, measure=700, route_mode="min",
                        vcs_per_class=2)
    cfg_val = SimConfig(warmup=300, measure=700, route_mode="val",
                        vcs_per_class=2)
    thr_min = Simulator(net, cfg_min, pat).run(0.5).throughput_per_chip
    thr_val = Simulator(net, cfg_val, pat).run(0.5).throughput_per_chip
    assert thr_val > 3.0 * thr_min


@pytest.mark.slow
def test_ugal_adaptive_best_of_both():
    """Beyond-paper: UGAL-G keeps minimal-level uniform throughput while
    recovering most of VAL's worst-case gain (min/VAL per Fig. 13)."""
    net = T.build_switchless(T.paper_radix16_switchless(), "ugal-net")
    wc = TR.worst_case(net)
    uni = TR.uniform(net)
    res = {}
    for mode in ("min", "ugal"):
        cfg = SimConfig(route_mode=mode, vcs_per_class=2, warmup=250,
                        measure=600)
        res[mode, "wc"] = Simulator(net, cfg, wc).run(0.5).throughput_per_chip
        res[mode, "uni"] = Simulator(net, cfg, uni).run(
            0.5).throughput_per_chip
    assert res["ugal", "wc"] > 5 * res["min", "wc"]
    assert res["ugal", "uni"] > 0.9 * res["min", "uni"]


@pytest.mark.slow
def test_hotspot_inject_mask():
    net = T.build_switchless(T.paper_radix16_switchless(g=8), "hot-net")
    pat, is_hot = TR.hotspot(net, num_hot=4, seed=0)
    cfg = SimConfig(warmup=300, measure=900, route_mode="min",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, pat, inject_mask=is_hot)
    r = sim.run(0.2)
    assert r.delivered_pkts > 0
    assert r.dropped_pkts >= 0
