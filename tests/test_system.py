"""End-to-end behaviour tests: training loop, checkpoint/restart, failure
injection, straggler detection, data pipeline determinism."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs.registry import get_config
from repro.data.pipeline import Prefetcher, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.optim.optimizer import OptConfig
from repro.runtime.fault_tolerance import (FailureInjector,
                                           FaultTolerantLoop,
                                           StragglerMonitor)
from repro.runtime.trainer import Trainer, TrainSetup


def _setup(tmp_path, arch="minicpm-2b", steps=40):
    cfg = get_config(arch + "-smoke")
    opt = OptConfig(lr=2e-3, warmup_steps=2, total_steps=steps,
                    schedule="wsd", weight_decay=0.0)
    setup = TrainSetup(model=cfg, opt=opt, attn_impl="naive", remat=False)
    mesh = make_host_mesh(model=1)
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq_len=32, seed=3)
    ckpt = Checkpointer(str(tmp_path / "ckpt"), keep=2)
    return setup, mesh, data, ckpt


def test_training_loss_decreases(tmp_path):
    setup, mesh, data, _ = _setup(tmp_path)
    tr = Trainer(setup, mesh, data)
    hist = tr.run(25)
    first = np.mean([h["nll"] for h in hist[:5]])
    last = np.mean([h["nll"] for h in hist[-5:]])
    assert np.isfinite(last)
    assert last < first - 0.3, (first, last)


def test_checkpoint_roundtrip_bitexact(tmp_path):
    setup, mesh, data, ckpt = _setup(tmp_path)
    tr = Trainer(setup, mesh, data, checkpointer=ckpt, ckpt_every=5)
    tr.run(10)
    # continue 5 more steps, then restore to step 10 and rerun
    ref_params = jax.tree.map(np.asarray, tr.params)
    tr.run(5)
    tr.restore(10)
    got = jax.tree.map(np.asarray, tr.params)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(a, b)
    assert tr.step == 10


def test_failure_injection_recovers_and_completes(tmp_path):
    setup, mesh, data, ckpt = _setup(tmp_path)
    tr = Trainer(setup, mesh, data, checkpointer=ckpt, ckpt_every=4)
    inj = FailureInjector(fail_at=(6, 13))
    loop = FaultTolerantLoop(tr, inj)
    hist = loop.run(20)
    assert tr.step == 20
    assert loop.restarts == 2
    events = [e["event"] for e in loop.log]
    assert events.count("failure") == 2
    assert events.count("restart") == 2
    assert np.isfinite(hist[-1]["nll"])


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=2.0, alpha=0.5)
    for step in range(10):
        assert not mon.observe(step, 0.10 + 0.001 * step)
    assert mon.observe(10, 1.0)  # 10x slower
    assert mon.events and mon.events[0]["action"] == "redispatch-to-backup"
    # EMA not polluted by the straggler observation
    assert mon.ema < 0.2


def test_data_pipeline_determinism_and_sharding():
    a = SyntheticTokens(1000, batch=4, seq_len=16, seed=5)
    b = SyntheticTokens(1000, batch=4, seq_len=16, seed=5)
    x, y = next(a), next(b)
    np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # restore mid-stream
    next(a)
    st = a.state()
    b.restore(st)
    np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])
    # different shards differ
    s0 = SyntheticTokens(1000, 4, 16, seed=5, shard_index=0, num_shards=2)
    s1 = SyntheticTokens(1000, 4, 16, seed=5, shard_index=1, num_shards=2)
    assert not np.array_equal(next(s0)["tokens"], next(s1)["tokens"])


def test_prefetcher_yields_everything():
    it = iter([{"i": np.asarray(i)} for i in range(7)])
    out = [b["i"].item() for b in Prefetcher(it, depth=2)]
    assert out == list(range(7))


def test_wsd_schedule_shape():
    from repro.optim.optimizer import schedule_lr
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    schedule="wsd", decay_frac=0.2, min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # end of warmup
    assert abs(lrs[79] - 1.0) < 1e-6          # stable plateau
    assert lrs[85] < 1.0                       # decaying
    assert abs(lrs[100] - 0.1) < 1e-2          # floor


def test_gradient_compression_error_feedback():
    from repro.optim.compression import (compress, decompress,
                                         ef_compress_tree,
                                         init_error_state)
    x = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    err = init_error_state(x)
    qt, err1 = ef_compress_tree(x, err)
    back = decompress(*qt["w"])
    np.testing.assert_allclose(back + err1["w"], x["w"], rtol=0, atol=1e-5)
    assert qt["w"][0].dtype == jnp.int8
