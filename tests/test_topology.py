"""Structural invariants of the constructed networks."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import topology as T


def small_net():
    return T.build_switchless(T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2,
                                                 g=5))


def test_channel_counts():
    p = T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5)
    net = T.build_switchless(p)
    R, ab, g = p.R, p.ab, 5
    num_cg = ab * g
    mesh = 2 * 2 * R * (R - 1) * num_cg
    local = ab * (ab - 1) * g
    assert (net.ch_type == T.MESH).sum() == mesh
    assert (net.ch_type == T.LOCAL).sum() == local
    # at least one global link per W-group pair, each direction
    assert (net.ch_type == T.GLOBAL).sum() >= g * (g - 1)
    assert (net.ch_type == T.INJECT).sum() == net.num_terminals
    assert (net.ch_type == T.EJECT).sum() == net.num_terminals


def test_local_links_connect_correct_cgroups():
    net = small_net()
    t = net.tables
    for e in np.where(net.ch_type == T.LOCAL)[0]:
        s, d = net.ch_src[e], net.ch_dst[e]
        assert t["node_wg"][s] == t["node_wg"][d]
        assert t["node_cg"][s] != t["node_cg"][d]


def test_global_links_connect_distinct_wgroups():
    net = small_net()
    t = net.tables
    for e in np.where(net.ch_type == T.GLOBAL)[0]:
        s, d = net.ch_src[e], net.ch_dst[e]
        assert t["node_wg"][s] != t["node_wg"][d]


def test_wgroup_fully_connected():
    """Every pair of W-groups has a global link (the Dragonfly property)."""
    net = small_net()
    t = net.tables
    g = net.meta["g"]
    seen = set()
    for e in np.where(net.ch_type == T.GLOBAL)[0]:
        seen.add((int(t["node_wg"][net.ch_src[e]]),
                  int(t["node_wg"][net.ch_dst[e]])))
    for i in range(g):
        for j in range(g):
            if i != j:
                assert (i, j) in seen


def test_cgroup_fully_connected_within_wgroup():
    net = small_net()
    t = net.tables
    ab = net.meta["ab"]
    pairs = set()
    for e in np.where(net.ch_type == T.LOCAL)[0]:
        s, d = net.ch_src[e], net.ch_dst[e]
        pairs.add((int(t["node_wg"][s]), int(t["node_cg"][s]),
                   int(t["node_cg"][d])))
    for wg in range(net.meta["g"]):
        for c1 in range(ab):
            for c2 in range(ab):
                if c1 != c2:
                    assert (wg, c1, c2) in pairs


def test_port_labeling_property2():
    """Property 2: for every C-group, local ports to lower C-groups are at
    lower labels than every global port, which are lower than local ports to
    higher C-groups."""
    p = T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5)
    net = T.build_switchless(p)
    lp = net.tables["local_port"]
    ab, h, k = p.ab, p.h, p.k
    for cg in range(ab):
        down = [lp[cg, peer] for peer in range(cg)]
        up = [lp[cg, peer] for peer in range(cg + 1, ab)]
        glob = list(range(cg, cg + h))
        if down:
            assert max(down) < min(glob)
        if up:
            assert max(glob) < min(up)
        labels = sorted(down + glob + up)
        assert labels == sorted(set(labels)), "labels must be distinct"
        assert max(labels) < k


def test_dragonfly_baseline_structure():
    p = T.SwitchDragonflyParams(t=2, l=3, gl=2, g=5)
    net = T.build_switch_dragonfly(p)
    assert net.num_nodes == 5 * 4
    assert net.num_terminals == 40
    assert (net.ch_type == T.LOCAL).sum() == 5 * 4 * 3
    assert (net.ch_type == T.GLOBAL).sum() >= 5 * 4


@given(a=st.integers(1, 2), b=st.integers(1, 3), m=st.integers(1, 2),
       n=st.sampled_from([4, 6, 8]), g=st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_switchless_builds_and_validates(a, b, m, n, g):
    p = T.SwitchlessParams(a=a, b=b, m=m, n=n, noc=2)
    if p.h < 1 or g > p.g_max:
        return
    net = T.build_switchless(T.SwitchlessParams(a=a, b=b, m=m, n=n, noc=2,
                                                g=g))
    net.validate()
    assert net.num_terminals == p.ab * p.R * p.R * g
    # every external port that is wired appears exactly once as a source
    ext = net.tables["ext_out"]
    wired = ext[ext >= 0]
    assert len(np.unique(wired)) == len(wired)
