"""Traffic pattern invariants: destination ranges, the out-of-range guard,
permutation fixed-point handling, and the batched-key path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.simulator import SimConfig, Simulator


@pytest.fixture(scope="module")
def net():
    # 2 W-groups so group-structured patterns (worst_case, hotspot) are
    # exercised; T=64 is NOT a power of two times anything special for the
    # bit patterns (b = 6 bits covers 0..63 exactly here, so also try the
    # guard separately on a non-power-of-two below).
    p = T.SwitchlessParams(a=2, b=1, m=2, n=4, noc=2, g=2)
    return T.build_switchless(p, "traffic-net")


def _assert_in_range(dest, T_):
    d = np.asarray(dest)
    assert d.shape == (T_,)
    assert (d >= 0).all() and (d < T_).all()


def test_all_patterns_in_range(net):
    T_ = net.num_terminals
    key = jax.random.PRNGKey(0)
    for name, mk in TR.PATTERNS.items():
        pat = mk(net)
        for t in (0, 7):
            _assert_in_range(pat(jax.random.fold_in(key, t), t), T_)
    hot, _ = TR.hotspot(net, num_hot=2, seed=0)
    _assert_in_range(hot(key, 0), T_)
    for bi in (False, True):
        _assert_in_range(TR.ring_allreduce(net, bidirectional=bi)(key, 0), T_)


def test_uniform_never_self(net):
    pat = TR.uniform(net)
    for s in range(4):
        d = np.asarray(pat(jax.random.PRNGKey(s), 0))
        assert (d != np.arange(net.num_terminals)).all()


def test_guard_maps_out_of_range_to_self():
    T_ = 12  # non-power-of-two: bit patterns can exceed T-1
    dest = np.array([0, 5, 11, 12, 15, 200] + [1] * (T_ - 6))
    g = TR._guard(dest, T_)
    src = np.arange(T_)
    oor = dest >= T_
    assert (g[oor] == src[oor]).all()
    assert (g[~oor] == dest[~oor]).all()
    assert (g < T_).all()


def test_bit_patterns_guarded_on_non_pow2(net):
    # the fixture net has T = num_terminals; whatever it is, destinations
    # must be guarded into range
    T_ = net.num_terminals
    for mk in (TR.bit_reverse, TR.bit_shuffle, TR.bit_transpose):
        _assert_in_range(mk(net)(jax.random.PRNGKey(0), 0), T_)


def test_permutation_fixed_points_silently_dropped(net):
    """A pattern that is ALL fixed points generates zero packets: the
    simulator treats dest == src as "don't inject" (no drops, no traffic)."""
    identity = TR._perm_pattern(np.arange(net.num_terminals))
    cfg = SimConfig(warmup=50, measure=150, vcs_per_class=2)
    sim = Simulator(net, cfg, identity)
    r = sim.run(1.0)
    assert r.generated_pkts == 0
    assert r.delivered_pkts == 0
    assert r.dropped_pkts == 0


def test_batched_key_path_matches_per_lane(net):
    pat = TR.uniform(net)
    keys = TR.split_lanes(jax.random.PRNGKey(42), 3)
    batched = TR.batched(pat)(keys, 0)
    assert batched.shape == (3, net.num_terminals)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(pat(keys[i], 0)))
    # permutation patterns broadcast over the lane axis
    perm = TR.bit_reverse(net)
    b = TR.batched(perm)(keys, 0)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(b[i]),
                                      np.asarray(perm(keys[i], 0)))
