"""Warm (time-varying) faults: FaultSchedule semantics, per-epoch
deadlock freedom, the routing-package public API, cold/warm engine parity,
packet conservation across an epoch boundary, and the fault-aware
adaptive misroute stage."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core import routing as R
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.engine import build_lane, make_state
from repro.core.engine import sweep as sweep_mod
from repro.core.simulator import SimConfig, Simulator


@pytest.fixture(scope="module")
def small_net():
    return T.build_switchless(
        T.SwitchlessParams(a=1, b=2, m=2, n=4, noc=2, g=4), "warm-small")


@pytest.fixture(scope="module")
def multi_wg_net():
    return T.build_switchless(
        T.SwitchlessParams(a=2, b=2, m=2, n=4, noc=2, g=5), "warm-multiwg")


def _link_faults(net, frac, seed, types=(T.MESH, T.LOCAL, T.GLOBAL),
                 vc_mode="updown", base=None):
    return T.sample_link_faults(net, frac, np.random.default_rng(seed),
                                types=types, vc_mode=vc_mode, base=base)


# --- FaultSchedule semantics -------------------------------------------------

def test_schedule_construction_validates(small_net):
    f = _link_faults(small_net, 0.05, 0)
    with pytest.raises(ValueError):
        T.FaultSchedule(())                       # no epochs
    with pytest.raises(ValueError):
        T.FaultSchedule(((5, f),))                # first epoch not at 0
    with pytest.raises(ValueError):
        T.FaultSchedule(((0, f), (10, f), (10, f)))  # not increasing
    with pytest.raises(ValueError):
        T.FaultSchedule(((0, "nope"),))           # not a FaultSet
    sch = T.FaultSchedule(((0, T.FaultSet()), (100, f)))
    assert sch.num_epochs == 2 and not sch.is_static and not sch.is_empty
    assert sch.final == f
    assert sch.epoch_at(0) == 0 and sch.epoch_at(99) == 0
    assert sch.epoch_at(100) == 1 and sch.epoch_at(10**6) == 1
    assert T.FaultSchedule.cold(f).is_static
    assert T.as_fault_schedule(None).is_empty
    assert T.as_fault_schedule(f).final == f
    assert T.final_faults(sch) == f and T.final_faults(f) == f
    # schedules are hashable (lane memoization keys)
    assert len({sch, sch, T.FaultSchedule.cold(f)}) == 2


def test_schedule_compose_and_union_base(small_net):
    f1 = _link_faults(small_net, 0.04, 1)
    f2 = _link_faults(small_net, 0.04, 2)
    sch = T.FaultSchedule(((0, T.FaultSet()), (50, f1)))
    u = sch.union_base(f2)
    assert u.epochs[0] == (0, f2)
    assert u.epochs[1] == (50, f1.union(f2))
    # compose_faults: set x set, schedule x set, schedule x schedule
    assert T.compose_faults(f1, None) == f1
    assert T.compose_faults(None, sch) == sch
    assert T.compose_faults(f2, sch) == u
    sch2 = T.FaultSchedule(((0, T.FaultSet()), (80, f2)))
    m = T.compose_faults(sch, sch2)
    assert [c for c, _ in m.epochs] == [0, 50, 80]
    assert m.final == f1.union(f2)


def test_schedule_validate_rejects_unroutable_epoch(multi_wg_net):
    net = multi_wg_net
    # kill every global link of one W-group pair in the second epoch
    t = net.tables
    chs = []
    for r in range(t["glob_route_cg"].shape[-1]):
        cg = t["glob_route_cg"][0, 1, r]
        if cg >= 0:
            ch = t["ext_out"][cg, t["glob_route_port"][0, 1, r]]
            if ch >= 0:
                chs.append(int(ch))
    bad = T.FaultSchedule(((0, T.FaultSet()),
                           (40, T.FaultSet(dead_ch=tuple(chs)))))
    with pytest.raises(ValueError, match="cycle 40"):
        bad.validate(net, "updown")
    # building an engine lane validates every epoch too
    cfg = SimConfig(vc_mode="updown")
    with pytest.raises(ValueError):
        build_lane(net, cfg, bad)


# --- routing package ---------------------------------------------------------

def test_routing_package_public_api(multi_wg_net):
    """The routing/ package keeps the monolithic module's public API and
    adds the RoutePipeline protocol."""
    # historical imports (seed_reference and the engine rely on these)
    from repro.core.routing import (assert_deadlock_free, build_updown_tables,
                                    make_route_fn, make_route_kernel,
                                    meta_cg_count, meta_update, num_vcs,
                                    route_tables, trace_paths)
    net = multi_wg_net
    pipe = R.make_pipeline(net, "updown")
    assert isinstance(pipe, R.RoutePipeline)
    assert pipe.num_vcs(nonminimal=True) == num_vcs("switchless", "updown",
                                                    True)
    # bind() == make_route_fn: same outputs on the same inputs
    rf_a = pipe.bind()
    rf_b = make_route_fn(net, "updown")
    rng = np.random.default_rng(0)
    cur = jnp.asarray(rng.integers(0, net.num_nodes, 64))
    dest = jnp.asarray(rng.integers(0, net.num_terminals, 64))
    mis = jnp.full((64,), -1, jnp.int32)
    meta = jnp.zeros(64, jnp.int32)
    for a, b in zip(rf_a(cur, dest, mis, meta), rf_b(cur, dest, mis, meta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # epoch_tables stacks one table set per epoch
    f = _link_faults(net, 0.06, 3)
    sch = T.FaultSchedule(((0, T.FaultSet()), (70, f)))
    starts, tabs = pipe.epoch_tables(sch)
    assert list(np.asarray(starts)) == [0, 70]
    for k, v in tabs.items():
        assert v.shape[0] == 2, k
    # epoch 0 tables == pristine tables bit-for-bit
    prist = route_tables(net, "updown")
    for k in prist:
        np.testing.assert_array_equal(np.asarray(tabs[k][0]),
                                      np.asarray(prist[k]))


def test_schedule_deadlock_free_every_epoch(multi_wg_net):
    net = multi_wg_net
    f1 = _link_faults(net, 0.05, 7)
    f2 = _link_faults(net, 0.05, 8, base=f1)
    sch = T.FaultSchedule(((0, T.FaultSet()), (60, f1), (120, f2)))
    rng = np.random.default_rng(1)
    edges = R.assert_schedule_deadlock_free(net, "updown", True, rng, sch,
                                            n_pairs=1500)
    assert len(edges) == 3 and all(e > 0 for e in edges)


def test_registered_warm_scenarios_deadlock_free_all_modes():
    """Acceptance: every epoch of every registered warm-fault scenario's
    sampled schedules is deadlock-free under all three vc_modes."""
    from repro.exp import registry
    rng = np.random.default_rng(5)
    checked = 0
    for name in registry.list_scenarios():
        spec = registry.get_scenario(name)
        warm = [f for f in spec.axes.faults if f.is_warm]
        if not warm:
            continue
        net = spec.topologies[0].build()
        for f in warm:
            sch = f.sample(net, spec.routings[0].vc_mode,
                           spec.axes.seeds[0])
            assert isinstance(sch, T.FaultSchedule)
            for mode in ("baseline", "updown", "updown_merged"):
                try:
                    sch.validate(net, mode)
                except ValueError:
                    # baseline routes deterministically and only
                    # tolerates GLOBAL-link faults; registered router /
                    # mesh fault populations (e.g. the fleet levels)
                    # are legitimately rejected there — the up*/down*
                    # modes must still prove out
                    assert mode == "baseline"
                    continue
                R.assert_schedule_deadlock_free(net, mode, True, rng, sch,
                                                n_pairs=600)
            checked += 1
    assert checked >= 2  # smoke_warm_faults + yield_curve populations


# --- engine parity and conservation ------------------------------------------

def test_static_schedule_matches_cold_run_faults_lane_for_lane(small_net):
    """Acceptance: the all-epochs-identical schedule reproduces the cold
    `run_faults` grid bit-for-bit, and a mixed (rates x seeds x schedules)
    grid — including different epoch counts — runs in ONE compile."""
    net = small_net
    f = _link_faults(net, 0.08, 11)
    cfg = SimConfig(warmup=107, measure=389, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    static2 = T.FaultSchedule(((0, f), (251, f)))
    static3 = T.FaultSchedule(((0, f), (151, f), (301, f)))
    seeds = (0, 1)
    before = sweep_mod.compile_counter()
    grid = sim.sweep_faults(0.3, [f, static2, static3], seeds=seeds)
    assert sweep_mod.compile_counter() - before == 1
    assert grid.compile_count == 1
    for j in range(len(seeds)):
        cold = grid.result(0, j)
        for i in (1, 2):
            warm = grid.result(i, j)
            assert warm.delivered_pkts == cold.delivered_pkts
            assert warm.generated_pkts == cold.generated_pkts
            assert warm.dropped_pkts == cold.dropped_pkts
            assert warm.avg_latency == cold.avg_latency
            assert warm.hops_by_type == cold.hops_by_type


def test_warm_schedule_degrades_but_beats_cold(small_net):
    """A mid-run die-off sits between pristine and cold-from-0 delivery:
    the pre-onset cycles run at full capacity."""
    net = small_net
    f = _link_faults(net, 0.10, 23)
    cfg = SimConfig(warmup=0, measure=600, vc_mode="updown",
                    vcs_per_class=2)
    sim = Simulator(net, cfg, TR.uniform(net))
    warm = T.FaultSchedule(((0, T.FaultSet()), (300, f)))
    r_prist = sim.run(0.45)
    r_warm = sim.run(0.45, faults=warm)
    r_cold = sim.run(0.45, faults=f)
    assert r_cold.delivered_pkts <= r_warm.delivered_pkts \
        <= r_prist.delivered_pkts
    assert r_cold.delivered_pkts < r_prist.delivered_pkts


def test_conservation_across_epoch_boundary(small_net):
    """Acceptance (drain semantics): generated == delivered + in-flight +
    dropped at every cycle, across the epoch boundary, and the network
    drains completely once injection stops (no buffered packet is ever
    silently dropped when links die mid-run).  The per-cycle arithmetic
    lives in the shared `conservation_trace` helper (conftest.py), which
    test_reliability.py applies across the whole {pristine, cold, warm,
    repair} x {jnp, fused, compact} matrix."""
    from conftest import conservation_trace
    net = small_net
    f = _link_faults(net, 0.12, 31)
    sch = T.FaultSchedule(((0, T.FaultSet()), (40, f)))
    cfg = SimConfig(warmup=0, measure=1, vc_mode="updown", vcs_per_class=2)
    trace = conservation_trace(net, cfg, faults=sch, cycles=500,
                               rate=0.08, stop_inject_at=80)
    assert trace[40]["inflight"] > 0, "no traffic in flight at the boundary"
    last = trace[-1]
    assert last["generated"] > 100
    assert last["inflight"] == 0, "network must drain once injection stops"
    assert last["generated"] == last["delivered"] + last["dropped"]


def test_stranded_packet_request_never_granted(small_net):
    """A request for the -1 non-channel (warm-stranded packet) must never
    win arbitration or corrupt the trailing eject channel's accounting."""
    net = small_net
    cfg = SimConfig(vc_mode="updown", vcs_per_class=1)
    consts, route_kernel = engine.build_consts(net, cfg)
    fl = build_lane(net, cfg)
    state = make_state(net, cfg, consts["NV"])
    # hand-build: one packet at the head of (channel 0, vc 0) whose route
    # is forced to -1 by a crafted all-dead next-hop table
    state = state.replace(
        b_count=state.b_count.at[0, 0].set(1),
        b_pkt=state.b_pkt.at[0, 0, 0].set(
            jnp.asarray([5, 0, -1, 0, 0], jnp.int32)))
    crafted = dict(fl, ud_nh=jnp.full_like(fl["ud_nh"], -1))
    arbitrate = engine.make_arbitrate_fn(net, cfg, consts, route_kernel)
    req, win, won_ch = arbitrate(state, 0, crafted)
    out0 = int(np.asarray(req.out)[0])
    assert out0 == -1
    assert not bool(np.asarray(win)[0])
    assert not np.asarray(won_ch)[-1], "phantom grant on trailing eject"


# --- fault-aware adaptive misrouting -----------------------------------------

def test_adaptive_lane_tables(multi_wg_net):
    """Pristine lanes carry identity adaptive tables; faulted lanes mask
    dead pairs and penalize degraded W-groups."""
    net = multi_wg_net
    cfg = SimConfig(route_mode="ugal", vc_mode="updown")
    fl0 = build_lane(net, cfg)
    assert bool(np.asarray(fl0["glob_ok"]).all())
    assert (np.asarray(fl0["wg_penalty"]) == 0).all()
    f = _link_faults(net, 0.15, 41, types=(T.MESH, T.LOCAL))
    fl = build_lane(net, cfg, f)
    pen = np.asarray(fl["wg_penalty"])
    assert pen.max() > 0
    frac = T.wg_channel_alive_frac(net, f)
    np.testing.assert_array_equal(
        pen, np.round(engine.state.UGAL_WG_PENALTY_SCALE * (1 - frac)))


def test_misroute_masked_by_global_liveness(multi_wg_net):
    """VAL candidates whose misroute path lost all global links fall back
    to minimal."""
    net = multi_wg_net
    cfg = SimConfig(route_mode="val", vcs_per_class=1)
    consts, _ = engine.build_consts(net, cfg)
    gen_mis = engine.make_misroute_fn(net, cfg, consts)
    fl = build_lane(net, cfg)
    T_ = net.num_terminals
    tpw = net.meta["terms_per_wg"]
    dest = jnp.full((T_,), (net.meta["g"] - 1) * tpw, dtype=jnp.int32)
    key = jax.random.PRNGKey(7)
    mis_ok = np.asarray(gen_mis(key, dest, jnp.zeros(
        (net.num_channels, consts["NV"]), jnp.int32), fl))
    assert (mis_ok >= 0).any()
    # kill the candidate set: no W-group pair keeps an alive global link
    dead = dict(fl, glob_ok=jnp.zeros_like(fl["glob_ok"]))
    mis_dead = np.asarray(gen_mis(key, dest, jnp.zeros(
        (net.num_channels, consts["NV"]), jnp.int32), dead))
    assert (mis_dead == -1).all()


def test_ugal_biased_away_from_degraded_wgroup(multi_wg_net):
    """The degradation penalty flips a borderline UGAL decision back to
    minimal for candidates in a degraded W-group."""
    net = multi_wg_net
    cfg = SimConfig(route_mode="ugal", vcs_per_class=1, ugal_threshold=3)
    consts, _ = engine.build_consts(net, cfg)
    gen_mis = engine.make_misroute_fn(net, cfg, consts)
    fl = build_lane(net, cfg)
    g = net.meta["g"]
    tpw = net.meta["terms_per_wg"]
    T_ = net.num_terminals
    wd = g - 1
    dest = jnp.full((T_,), wd * tpw, dtype=jnp.int32)
    # congest the minimal-path sensor so UGAL wants to misroute
    watch = np.asarray(fl["ugal_watch"])
    occ = np.zeros((net.num_channels, consts["NV"]), dtype=np.int32)
    occ[watch[:, wd, 0][watch[:, wd, 0] >= 0]] = cfg.buf_pkts
    key = jax.random.PRNGKey(9)
    mis_nopen = np.asarray(gen_mis(key, dest, jnp.asarray(occ), fl))
    took = mis_nopen >= 0
    assert took.any()
    # penalize EVERY candidate W-group heavily -> all decisions minimal
    pen = dict(fl, wg_penalty=jnp.full((g,), 64, jnp.int32))
    mis_pen = np.asarray(gen_mis(key, dest, jnp.asarray(occ), pen))
    assert (mis_pen == -1).all()


def test_warm_ugal_end_to_end(multi_wg_net):
    """A warm global die-off under adaptive routing still delivers (the
    smoke_warm_faults scenario shape, one compile)."""
    net = multi_wg_net
    sch = T.FaultSchedule(((0, T.FaultSet()),
                           (90, _link_faults(net, 0.3, 51,
                                             types=(T.GLOBAL,),
                                             vc_mode="baseline"))))
    cfg = SimConfig(warmup=60, measure=240, vc_mode="baseline",
                    route_mode="ugal", vcs_per_class=1)
    sim = Simulator(net, cfg, TR.uniform(net), faults=sch)
    r = sim.run(0.4)
    assert r.dropped_pkts == 0
    assert r.delivered_pkts > 0.8 * r.generated_pkts
